"""Fig. 3 / Eq. 2-3 + the tiered activation store (server memory manager).

OAFL: μ = (K+1)·μ_model + K·μ_act (a server-side model per device).
FedOptima: μ = μ_model + ω·μ_act (one model + a global activation cap).

μ_model / μ_act are DERIVED from the actual partitioned model profile
(``core/partition.py``: per-layer param/activation bytes + the Eq. 6-8
split point under the testbed's device rates) instead of hardcoded byte
constants, and the analytic curves are backed by two empirical runs:

* the event simulator asserts the flow-control cap on every enqueue, so
  Σ|Q_act| ≤ ω (pool_cap=0) or ≤ ω + pool (tiered) holds *during* the
  run, not just at the end;
* a K ≫ ω run drives the ControlPlane's spill/fill planning against a
  real ``repro.memory.ActivationStore`` (fp32 and int8 spill), recording
  peak bytes per tier and spill/fill/eviction counts — the ω ring as a
  cache over a host pool rather than a hard ceiling.

Results ride ``BENCH_memory.json``; honors ``--smoke`` / ``BENCH_SMOKE``.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.control_plane import ControlPlane
from repro.core.partition import cnn_profile, select_split
from repro.core.simulation import SimCluster, simulate_fedoptima
from repro.memory import ActivationStore
from repro.models import cnn

from . import common
from .common import (MOBILENET_SPLIT, OMEGA, Row, bench_duration,
                     fedoptima_control, testbed_b, timed)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_memory.json")

BATCH = 32   # activation-batch granularity of the paper's Eq. 2-3


def derived_mu(record) -> tuple[float, float, list[Row]]:
    """μ_model (server-side block) and μ_act (one activation batch) from
    the profiled MobileNetV3-ish model partitioned by Eq. 6-8 under
    testbed B's device rates — provenance rows instead of constants."""
    cfg = cnn.mobilenetv3ish_config(n_classes=200, img_size=64)
    prof = cnn_profile(cfg)
    cluster = testbed_b()
    l = select_split(prof, cluster.dev_flops.tolist(),
                     cluster.dev_bw.tolist(), batch=BATCH)
    mu_act = prof.out_bytes[l - 1] * BATCH
    full_bytes = prof.param_bytes_cum[-1]
    mu_model = full_bytes - prof.param_bytes_cum[l - 1]   # server-side block
    rows = [Row("memory/derived_mu", 0.0,
                f"arch=mobilenetv3ish;l_split={l}/{prof.n_units}"
                f";mu_model_MB={mu_model/1e6:.2f}"
                f";mu_act_MB={mu_act/1e6:.2f}"
                f";full_model_MB={full_bytes/1e6:.2f}")]
    record["derived"] = {"arch": "mobilenetv3ish-64", "l_split": l,
                         "n_units": prof.n_units, "batch": BATCH,
                         "mu_model_bytes": mu_model,
                         "mu_act_bytes": mu_act,
                         "full_model_bytes": full_bytes}
    return mu_model, mu_act, rows


def eq_curves(mu_model: float, mu_act: float, record) -> list[Row]:
    rows, curve = [], {}
    for K in (8, 16, 32, 64, 128, 256):
        oafl = (K + 1) * mu_model + K * mu_act
        fed = mu_model + OMEGA * mu_act
        curve[str(K)] = {"oafl_eq2": oafl, "fedoptima_eq3": fed}
        rows.append(Row(f"memory/K={K}/oafl_eq2", 0.0,
                        f"GB={oafl/1e9:.3f}"))
        rows.append(Row(f"memory/K={K}/fedoptima_eq3", 0.0,
                        f"GB={fed/1e9:.3f}"))
    # 8 GB server bound (paper: OAFL caps out at tens of devices)
    k_max_oafl = int((8e9 - mu_model) / (mu_model + mu_act))
    rows.append(Row("memory/oafl_max_devices_8GB", 0.0, f"K={k_max_oafl}"))
    rows.append(Row("memory/fedoptima_max_devices_8GB", 0.0, "K=unbounded"))
    record["eq_curves_bytes"] = curve
    record["oafl_max_devices_8GB"] = k_max_oafl
    return rows


def sim_cap_rows(record) -> list[Row]:
    """Empirical cap through the event simulator: strict ω, then the
    tiered budget with K = 4ω devices (impossible under the hard cap)."""
    rows = []
    dur = bench_duration(120.0, smoke=20.0)
    sims = {}
    for K in (8, 32, 128):
        cluster = SimCluster(dev_flops=np.full(K, 5e9),
                             dev_bw=np.full(K, 100e6 / 8), srv_flops=4e11)
        cp = fedoptima_control(cluster)
        m, us = timed(simulate_fedoptima, MOBILENET_SPLIT, cluster,
                      duration=dur, omega=OMEGA, control=cp)
        rows.append(Row(f"memory/K={K}/sim_peak_buffer", us,
                        f"max_buffered={m.max_buffered};omega={OMEGA}"
                        f";cp_peak={cp.peak_buffered}"))
        assert m.max_buffered <= OMEGA
        assert cp.peak_buffered <= OMEGA and cp.flow.within_cap
        sims[str(K)] = {"max_buffered": m.max_buffered,
                        "peak_buffered": cp.peak_buffered}
    # K = 4ω with a slow server: buffering past ω is the point — the old
    # strict path would have tripped its max_buffered <= ω assertion
    K, pool = 4 * OMEGA, 3 * OMEGA
    cluster = SimCluster(dev_flops=np.full(K, 5e9),
                         dev_bw=np.full(K, 100e6 / 8), srv_flops=4e10)
    cp = fedoptima_control(cluster, pool_cap=pool)
    m, us = timed(simulate_fedoptima, MOBILENET_SPLIT, cluster,
                  duration=dur, omega=OMEGA, pool_cap=pool, control=cp)
    mem = cp.memory_summary()
    assert cp.within_cap and m.max_buffered <= OMEGA + pool
    assert m.max_buffered > OMEGA, \
        (m.max_buffered, "tiered run never exceeded the old ω cap — slow "
         "the server down so the spill tier is exercised")
    rows.append(Row(f"memory/K={K}/sim_tiered_peak_buffer", us,
                    f"max_buffered={m.max_buffered};omega={OMEGA}"
                    f";pool={pool};spills={mem['spills']}"
                    f";fills={mem['fills']}"))
    sims[f"{K}_tiered"] = {"max_buffered": m.max_buffered, "pool": pool,
                           **mem}
    record["sim"] = {"duration_s": dur, "runs": sims}
    return rows


def tiered_store_rows(mu_act: float, record) -> list[Row]:
    """K ≫ ω pod-style planning run against the real ActivationStore:
    the ControlPlane plans spill/fill moves, host slot payloads move
    through the store, and the peak bytes per tier are measured."""
    rows = []
    omega, G = OMEGA, 4 * OMEGA
    pool = 3 * OMEGA                       # total capacity 4ω slots
    H, rounds = 2, 24                      # 12 stalled + 12 draining
    # one ring slot = one micro-iteration's combined emission (~G·μ_act);
    # smoke keeps arrays tiny — the planning path is identical
    per_group = 64 if common.SMOKE else \
        max(64, int(mu_act / BATCH / 4))   # fp32 elements per contribution
    rng = np.random.default_rng(0)

    def fresh_slot():
        return {"acts": rng.standard_normal((G, per_group)).astype(np.float32),
                "labels": rng.integers(0, 1000, (G, 8)).astype(np.int32)}

    runs = {}
    for quant in (False, True):
        cp = ControlPlane(G, omega, H, pool_cap=pool)
        store = ActivationStore(pool, quant=quant)
        ring = [fresh_slot() for _ in range(omega)]
        slot_bytes = sum(int(v.nbytes) for v in ring[0].values())
        spilled_total = 0
        for r in range(rounds):
            # first half: server stalled (writes pile into the spill
            # tier); second half: reads resume and the pool drains back
            reads = np.zeros(H, bool) if r < rounds // 2 else \
                np.ones(H, bool)
            produce = None if r < rounds // 2 else np.zeros((H, G), bool)
            plan = cp.plan_round(produce=produce, reads=reads)
            for key, s in plan.fill:
                ring[s] = store.fill(key)
            for s, key in plan.spill:
                store.spill(key, ring[s])
                spilled_total += 1
            for h in range(H):
                if plan.send_mask[h].any():
                    ring[int(plan.write_slot[h])] = fresh_slot()
            assert cp.within_cap, cp.memory_summary()
            cp.finish_round()
        mem = {**cp.memory_summary(), **store.summary()}
        assert mem["spills"] == mem["store_spills"] == spilled_total
        assert mem["fills"] == mem["store_fills"]
        assert mem["peak_pool"] > 0, "workload never spilled"
        assert store.n_fills == store.n_spills and len(store) == 0, \
            "pool failed to drain once the server caught up"
        tag = "int8" if quant else "fp32"
        rows.append(Row(
            f"memory/tiered_store/K={G}/omega={omega}/{tag}", 0.0,
            f"mesh_MB={omega*slot_bytes/1e6:.3f}"
            f";peak_pool_MB={mem['peak_pool_bytes']/1e6:.3f}"
            f";peak_pool_slots={mem['peak_pool']}/{pool}"
            f";spills={mem['spills']};fills={mem['fills']}"
            f";evictions={mem['evictions']}"))
        runs[tag] = {"mesh_tier_bytes": omega * slot_bytes,
                     "slot_bytes": slot_bytes, **mem}
    # int8 spill should shrink the pool's float payload ~4x
    ratio = runs["fp32"]["peak_pool_bytes"] / \
        max(runs["int8"]["peak_pool_bytes"], 1)
    rows.append(Row("memory/tiered_store/int8_compression", 0.0,
                    f"pool_bytes_ratio={ratio:.2f}"))
    assert ratio > 2.0, ratio
    record["tiered_store"] = {"G": G, "omega": omega, "pool_cap": pool,
                              "rounds": rounds, "H": H, "runs": runs,
                              "pool_bytes_ratio_fp32_int8": ratio}
    return rows


def main() -> list[Row]:
    record: dict = {"smoke": common.SMOKE}
    mu_model, mu_act, rows = derived_mu(record)
    rows += eq_curves(mu_model, mu_act, record)
    rows += sim_cap_rows(record)
    rows += tiered_store_rows(mu_act, record)
    common.write_record(OUT_PATH, record)
    rows.append(Row("memory/json", 0.0, f"wrote={os.path.basename(OUT_PATH)}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
