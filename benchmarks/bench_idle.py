"""Fig. 8/9: server + device idle time per method, both testbeds.

FedOptima runs through the integrated ControlPlane (scheduler + flow
control + staleness accounting); the ω-cap (Eq. 3) is asserted on every
enqueue during the run and on the recorded peak afterwards.  Every
protocol runs with a sim-domain span tracer attached, and the recorded
timelines feed :func:`repro.obs.idle.attribute_idle` — the idle fraction
each method reports is decomposed into *task-dependency* idle (blocked
on the other side of the split), *straggler* idle (waiting on slower
peers), warmup (pipeline fill) and offline time, per protocol.

Also measures RoundExecutor overlap (the HOST-side dependency idle time
the pipelined driver hides): window=1 (synchronous) vs window=2 (double-
buffered) wall per round on a testbed-modeled workload, plus the hidden
host-plan milliseconds and peak rounds in flight.  Results — including
the window deltas and the per-protocol ``idle_attribution`` tables —
are written to ``BENCH_idle.json``.
"""
from __future__ import annotations

import os

from repro.core.simulation import simulate_fedoptima
from repro.obs.idle import attribute_idle
from repro.obs.metrics import MetricsRegistry

from . import common
from .common import (MOBILENET_SPLIT, OMEGA, Row, TRANSFORMER6_SPLIT,
                     VGG5_SPLIT, bench_duration, executor_overlap,
                     run_protocol_grid, testbed_a, testbed_b, timed,
                     write_record)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_idle.json")


def run(model, cluster, tag, record, registry):
    dur = bench_duration(600.0)
    rows = []
    results, _, cp = run_protocol_grid(model, cluster, duration=dur,
                                       registry=registry, trace=True)
    assert cp.peak_buffered <= OMEGA, (cp.peak_buffered, OMEGA)
    attribution = {}
    base_srv, base_dev = [], []
    for name, r in results.items():
        m, us = r["metrics"], r["us"]
        attr = attribute_idle(r["tracer"], duration=dur)
        attribution[name] = {"server": attr["server"],
                             "devices": attr["devices"],
                             "warmup_end_s": attr["warmup_end_s"],
                             "steady": m.steady_summary()}
        srv_dep = attr["server"]["task_dependency_frac"]
        srv_str = attr["server"]["straggler_frac"]
        extra = f";peak_buf={cp.peak_buffered}" if name == "fedoptima" \
            else ""
        rows.append(Row(
            f"idle/{tag}/{name}", us,
            f"srv_idle={m.srv_idle_frac:.3f};dev_idle="
            f"{m.dev_idle_frac:.3f};srv_dep={srv_dep:.3f};"
            f"srv_straggler={srv_str:.3f}{extra}"))
        if name != "fedoptima":
            base_srv.append(m.srv_idle_frac)
            base_dev.append(m.dev_idle_frac)
    fo = results["fedoptima"]["metrics"]
    red_srv = 1.0 - fo.srv_idle_frac / max(min(base_srv), 1e-9)
    red_dev = 1.0 - fo.dev_idle_frac / max(min(base_dev), 1e-9)
    rows.append(Row(f"idle/{tag}/reduction_vs_best_baseline", 0.0,
                    f"server={red_srv:.1%};device={red_dev:.1%}"))
    record[tag] = {"fedoptima_srv_idle": fo.srv_idle_frac,
                   "fedoptima_dev_idle": fo.dev_idle_frac,
                   "reduction_srv": red_srv, "reduction_dev": red_dev,
                   "profiles": fo.profiles.summary(),
                   "idle_attribution": attribution}
    return rows


def run_executor_overlap(model, cluster, tag, record):
    """Host idle fraction before (sync) vs after (pipelined): the measured
    host-plan/build time hidden behind device execution."""
    rounds = 8 if common.SMOKE else 20
    sync = executor_overlap(model, cluster, rounds=rounds, window=1)
    pipe = executor_overlap(model, cluster, rounds=rounds, window=2)
    hidden_ms = pipe["host_ms_hidden_per_round"]
    saved = sync["wall_s_per_round"] - pipe["wall_s_per_round"]
    # host idle fraction: exposed host time / wall, before vs after
    idle_before = sync["host_s_exposed"] / max(sync["wall_s"], 1e-9)
    idle_after = pipe["host_s_exposed"] / max(pipe["wall_s"], 1e-9)
    rows = [
        Row(f"idle/{tag}/executor_window1", 1e6 * sync["wall_s_per_round"],
            f"host_exposed_frac={idle_before:.3f};in_flight="
            f"{sync['peak_in_flight']}"),
        Row(f"idle/{tag}/executor_window2", 1e6 * pipe["wall_s_per_round"],
            f"host_exposed_frac={idle_after:.3f};in_flight="
            f"{pipe['peak_in_flight']};host_ms_hidden={hidden_ms:.2f}"),
        Row(f"idle/{tag}/executor_overlap_delta", 1e6 * saved,
            f"saved_ms_per_round={1e3 * saved:.2f};plan_us="
            f"{pipe['plan_us']:.0f}"),
    ]
    record[f"{tag}_executor"] = {
        "window1": sync, "window2": pipe,
        "delta": {"saved_s_per_round": saved,
                  "host_ms_hidden_per_round": hidden_ms,
                  "host_exposed_frac_before": idle_before,
                  "host_exposed_frac_after": idle_after,
                  # steady-state exposure excludes each window's warmup
                  # dispatches (nothing in flight to hide behind yet)
                  "host_s_exposed_steady_before":
                      sync["host_s_exposed_steady"],
                  "host_s_exposed_steady_after":
                      pipe["host_s_exposed_steady"],
                  "hidden_host_frac_steady":
                      pipe["hidden_host_frac_steady"],
                  "rounds_in_flight": pipe["peak_in_flight"]}}
    return rows


def run_sanitizer_overhead(model, cluster, tag, record):
    """Measured cost of ``--sanitize``: the same seeded churn scenario
    with and without the protocol sanitizer attached.  The sanitizer is
    read-only, so the two runs must produce identical metrics — asserted
    here — and the wall-clock ratio pins the overhead instead of guessing
    it."""
    from repro.analysis.sanitize import sanitized, suspended
    from repro.fleet.traces import diurnal_trace

    dur = bench_duration(600.0)
    trace = diurnal_trace(cluster.K, horizon=dur, interval=dur / 24.0,
                          day=dur / 2.0, on_frac=0.6, bw=cluster.dev_bw,
                          bw_jitter=0.3, seed=7)
    kw = dict(duration=dur, omega=OMEGA, fleet=trace, seed=11)
    with suspended():        # the plain leg must not see a global sanitizer
        m_plain, us_plain = timed(simulate_fedoptima, model, cluster, **kw)
        with sanitized() as san:
            m_san, us_san = timed(simulate_fedoptima, model, cluster, **kw)
    same = (m_plain.srv_idle_frac == m_san.srv_idle_frac
            and m_plain.dev_idle_frac == m_san.dev_idle_frac
            and m_plain.throughput == m_san.throughput)
    if not same or san.n_violations:
        raise RuntimeError(
            f"sanitizer perturbed the run or found violations: "
            f"metrics_equal={same}, violations={san.n_violations}")
    overhead = us_san / max(us_plain, 1e-9)
    rows = [Row(f"idle/{tag}/sanitizer_overhead", us_san,
                f"plain_us={us_plain:.1f};overhead_x={overhead:.3f};"
                f"events={san.n_events};violations=0")]
    record[f"{tag}_sanitizer"] = {
        "us_plain": us_plain, "us_sanitized": us_san,
        "overhead_x": overhead, "events": san.n_events,
        "violations": san.n_violations, "metrics_equal": same}
    return rows


def run_tracer_overhead(model, cluster, tag, record):
    """Measured cost of ``--trace``: the same seeded churn scenario with
    and without a span tracer attached.  The tracer only records — the
    two runs must produce identical metrics (asserted), and the measured
    wall ratio pins the overhead (target: <= 1.5x)."""
    from repro.fleet.traces import diurnal_trace
    from repro.obs.trace import Tracer, traced

    dur = bench_duration(600.0)
    trace = diurnal_trace(cluster.K, horizon=dur, interval=dur / 24.0,
                          day=dur / 2.0, on_frac=0.6, bw=cluster.dev_bw,
                          bw_jitter=0.3, seed=7)
    kw = dict(duration=dur, omega=OMEGA, fleet=trace, seed=11)
    m_plain, us_plain = timed(simulate_fedoptima, model, cluster, **kw)
    tr = Tracer(domain="sim")
    with traced(tr):
        m_tr, us_tr = timed(simulate_fedoptima, model, cluster, **kw)
    same = (m_plain.srv_idle_frac == m_tr.srv_idle_frac
            and m_plain.dev_idle_frac == m_tr.dev_idle_frac
            and m_plain.throughput == m_tr.throughput)
    if not same:
        raise RuntimeError(
            "tracer perturbed the run: traced metrics differ from the "
            f"plain leg ({m_plain.throughput} vs {m_tr.throughput})")
    overhead = us_tr / max(us_plain, 1e-9)
    rows = [Row(f"idle/{tag}/tracer_overhead", us_tr,
                f"plain_us={us_plain:.1f};overhead_x={overhead:.3f};"
                f"spans={len(tr.spans)};lanes={len(tr.lanes())}")]
    record[f"{tag}_tracer"] = {
        "us_plain": us_plain, "us_traced": us_tr,
        "overhead_x": overhead, "target_max_x": 1.5,
        "spans": len(tr.spans), "lanes": len(tr.lanes()),
        "metrics_equal": same}
    return rows


def main() -> list[Row]:
    record: dict = {"smoke": common.SMOKE, "duration_s": bench_duration(600.0)}
    registry = MetricsRegistry()
    rows = []
    rows += run(VGG5_SPLIT, testbed_a(), "A_vgg5", record, registry)
    rows += run(MOBILENET_SPLIT, testbed_b(), "B_mobilenet", record, registry)
    rows += run(TRANSFORMER6_SPLIT, testbed_a(), "A_transformer6", record,
                registry)
    rows += run_executor_overlap(VGG5_SPLIT, testbed_a(), "A_vgg5", record)
    rows += run_sanitizer_overhead(VGG5_SPLIT, testbed_a(), "A_vgg5", record)
    rows += run_tracer_overhead(VGG5_SPLIT, testbed_a(), "A_vgg5", record)
    write_record(OUT_PATH, record, registry=registry)
    rows.append(Row("idle/json", 0.0, f"wrote={os.path.basename(OUT_PATH)}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
