"""Fig. 8/9: server + device idle time per method, both testbeds.

FedOptima runs through the integrated ControlPlane (scheduler + flow
control + staleness accounting); the ω-cap (Eq. 3) is asserted on every
enqueue during the run and on the recorded peak afterwards."""
from __future__ import annotations

from repro.core.baselines import REGISTRY
from repro.core.simulation import simulate_fedoptima

from .common import (MOBILENET_SPLIT, OMEGA, Row, TRANSFORMER6_SPLIT,
                     VGG5_SPLIT, fedoptima_control, testbed_a, testbed_b,
                     timed)

DUR = 600.0


def run(model, cluster, tag):
    rows = []
    cp = fedoptima_control(cluster)
    m, us = timed(simulate_fedoptima, model, cluster, duration=DUR,
                  omega=OMEGA, control=cp)
    assert cp.peak_buffered <= OMEGA, (cp.peak_buffered, OMEGA)
    rows.append(Row(f"idle/{tag}/fedoptima", us,
                    f"srv_idle={m.srv_idle_frac:.3f};dev_idle={m.dev_idle_frac:.3f}"
                    f";peak_buf={cp.peak_buffered}"))
    best_srv, best_dev = m.srv_idle_frac, m.dev_idle_frac
    base_srv, base_dev = [], []
    for name, fn in REGISTRY.items():
        b, us = timed(fn, model, cluster, duration=DUR)
        rows.append(Row(f"idle/{tag}/{name}", us,
                        f"srv_idle={b.srv_idle_frac:.3f};dev_idle={b.dev_idle_frac:.3f}"))
        base_srv.append(b.srv_idle_frac)
        base_dev.append(b.dev_idle_frac)
    red_srv = 1.0 - best_srv / max(min(base_srv), 1e-9)
    red_dev = 1.0 - best_dev / max(min(base_dev), 1e-9)
    rows.append(Row(f"idle/{tag}/reduction_vs_best_baseline", 0.0,
                    f"server={red_srv:.1%};device={red_dev:.1%}"))
    return rows


def main() -> list[Row]:
    rows = []
    rows += run(VGG5_SPLIT, testbed_a(), "A_vgg5")
    rows += run(MOBILENET_SPLIT, testbed_b(), "B_mobilenet")
    rows += run(TRANSFORMER6_SPLIT, testbed_a(), "A_transformer6")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
