"""Table 2 + Fig. 6/7: accuracy with real JAX training driven by the event
simulator — FedOptima vs OAFL on homogeneous vs heterogeneous devices,
non-IID (Dirichlet 0.5) data.  Miniature scale (CPU) but live dynamics:
staleness, imbalance, scheduling."""
from __future__ import annotations

import numpy as np

from repro.core.baselines import simulate_oafl
from repro.core.learning import FedOptimaLearner, ModelAdapter, SplitLearner
from repro.core.simulation import (SimCluster, heterogeneous_cluster,
                                   simulate_fedoptima)
from repro.data.partitioner import dirichlet_partition
from repro.data.pipeline import DeviceDataset
from repro.data.synthetic import classification_dataset
from repro.models import cnn

from .common import Row, VGG5_SPLIT, timed

K = 8
DUR = 90.0


def _task(seed=0):
    data = classification_dataset(4096, 10, img_size=8, seed=seed, noise=2.5)
    parts = dirichlet_partition(data.y, K, alpha=0.5, seed=seed)
    cfg = cnn.vgg5_config(n_classes=10, img_size=8)
    adapter = ModelAdapter(cnn, cfg)
    datasets = [DeviceDataset(data.x[ix], data.y[ix], batch=32, seed=g)
                for g, ix in enumerate(parts)]
    return adapter, datasets, (data.x[:512], data.y[:512])


def _homog():
    return SimCluster(dev_flops=np.full(K, 6e9),
                      dev_bw=np.full(K, 100e6 / 8), srv_flops=3e11)


def main() -> list[Row]:
    rows = []
    for tag, cluster in (("homog", _homog()),
                         ("heterog", heterogeneous_cluster(K))):
        adapter, datasets, (xe, ye) = _task()
        fo = FedOptimaLearner(adapter, datasets, l_split=1, lr_d=0.05,
                              lr_s=0.05)
        _, us = timed(simulate_fedoptima, VGG5_SPLIT, cluster, duration=DUR,
                      omega=8, hooks=fo)
        acc_fo = fo.eval_accuracy(xe, ye)
        rows.append(Row(f"accuracy/{tag}/fedoptima", us, f"acc={acc_fo:.3f}"))

        adapter, datasets, _ = _task()
        oafl = SplitLearner(adapter, datasets, l_split=1, lr=0.05)
        _, us = timed(simulate_oafl, VGG5_SPLIT, cluster, duration=DUR,
                      hooks=oafl)
        acc_oafl = oafl.eval_accuracy(xe, ye)
        rows.append(Row(f"accuracy/{tag}/oafl", us, f"acc={acc_oafl:.3f}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
