"""Benchmark harness: one module per paper table/figure + the roofline
table.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run              # all
    PYTHONPATH=src python -m benchmarks.run idle comm    # subset
    PYTHONPATH=src python -m benchmarks.run --smoke idle throughput
                                         # CI wiring check (tiny configs)

``--sanitize`` runs every suite under the protocol sanitizer
(``repro.analysis.sanitize``): control-plane events are invariant-checked
online and any violation aborts the run.  Default ON under ``--smoke``
(the CI lane), off at full benchmark scale; ``--no-sanitize`` forces it
off.  A ``sanitize/<suite>`` row records events checked per suite.
"""
from __future__ import annotations

import sys

from . import (bench_ablation_aux, bench_ablation_sched, bench_accuracy,
               bench_communication, bench_faults, bench_fleet, bench_idle,
               bench_kernels, bench_memory, bench_partition,
               bench_resilience, bench_roofline, bench_throughput, common)

SUITES = {
    "communication": bench_communication,   # Fig. 2
    "memory": bench_memory,                 # Fig. 3 / Eq. 2-3
    "accuracy": bench_accuracy,             # Table 2, Fig. 6/7
    "idle": bench_idle,                     # Fig. 8/9
    "throughput": bench_throughput,         # Fig. 10/11
    "resilience": bench_resilience,         # Fig. 12/13
    "ablation_aux": bench_ablation_aux,     # Fig. 14
    "ablation_sched": bench_ablation_sched, # Fig. 15
    "partition": bench_partition,           # Eq. 6-8
    "roofline": bench_roofline,             # §Roofline (deliverable g)
    "kernels": bench_kernels,               # Pallas fwd/bwd vs references
    "fleet": bench_fleet,                   # shared-trace scenario compare
    "faults": bench_faults,                 # chaos plane: goodput under faults
}


#: Suites whose durations honor common.SMOKE / bench_duration.
SMOKE_SUITES = ("idle", "throughput", "memory", "fleet", "faults")


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    if smoke:
        argv.remove("--smoke")
        common.SMOKE = True
    sanitize = smoke                 # default: on in smoke, off at scale
    if "--sanitize" in argv:
        argv.remove("--sanitize")
        sanitize = True
    if "--no-sanitize" in argv:
        argv.remove("--no-sanitize")
        sanitize = False
    # bare --smoke runs only the smoke-aware suites: the others ignore the
    # flag and would silently run at full cost
    which = argv or (list(SMOKE_SUITES) if smoke else list(SUITES))
    ignored = [n for n in which if smoke and n not in SMOKE_SUITES]
    if ignored:
        print(f"# note: --smoke is ignored by suites {ignored} "
              "(full duration)", flush=True)
    print("name,us_per_call,derived")
    for name in which:
        mod = SUITES[name]
        if sanitize:
            from repro.analysis.sanitize import sanitized
            with sanitized() as san:
                rows = mod.main()
            for row in rows:
                print(row.csv(), flush=True)
            rep = san.report()
            print(common.Row(f"sanitize/{name}", 0.0,
                             f"events={rep['events']};"
                             f"violations={rep['n_violations']}").csv(),
                  flush=True)
        else:
            for row in mod.main():
                print(row.csv(), flush=True)


if __name__ == "__main__":
    main()
