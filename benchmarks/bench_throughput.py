"""Fig. 10/11: system throughput (samples/s) per method, both testbeds.

Also measures executor round throughput two ways on a testbed-modeled
workload:

* **window sweep {1, 2, 4, 8}** under bursty host load (periodic host
  spikes a shallow window can't hide) — rounds/s, steady-state
  hidden-host fraction and peak handle-ring bytes per window, the
  measured "how deep until host time is fully hidden" curve.
* **checkpoint-heavy A/B** (checkpoint_every=4, window=4): the legacy
  flush saver (drain the pipe, save, refill) versus
  checkpoint-without-flush (save from the round's dispatch-time handle
  while later rounds stay in flight).

Everything lands in ``BENCH_throughput.json`` (env-stamped).
"""
from __future__ import annotations

import os

from repro.obs.metrics import MetricsRegistry

from . import common
from .common import (MOBILENET_SPLIT, OMEGA, Row, TRANSFORMER12_SPLIT,
                     TRANSFORMER6_SPLIT, VGG5_SPLIT, bench_duration,
                     executor_overlap, run_protocol_grid, testbed_a,
                     testbed_b, write_record)

#: The executor sweep's pipeline depths.
WINDOWS = (1, 2, 4, 8)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_throughput.json")


def run(model, cluster, tag, record, registry):
    dur = bench_duration(600.0)
    rows = []
    results, _, cp = run_protocol_grid(model, cluster, duration=dur,
                                       registry=registry)
    assert cp.peak_buffered <= OMEGA
    fo = results["fedoptima"]["metrics"]
    best = 0.0
    for name, r in results.items():
        m = r["metrics"]
        steady = m.steady_summary()
        thr_steady = steady.get("throughput_steady", m.throughput)
        rows.append(Row(f"throughput/{tag}/{name}", r["us"],
                        f"samples_per_s={m.throughput:.1f}"
                        f";steady={thr_steady:.1f}"))
        if name != "fedoptima":
            best = max(best, m.throughput)
    speedup = fo.throughput / max(best, 1e-9)
    rows.append(Row(f"throughput/{tag}/speedup_vs_best_baseline", 0.0,
                    f"x={speedup:.2f}"))
    record[tag] = {"fedoptima_samples_per_s": fo.throughput,
                   "fedoptima_steady": fo.steady_summary(),
                   "speedup_vs_best_baseline": speedup}
    return rows


def run_executor_throughput(model, cluster, tag, record):
    """Window sweep {1, 2, 4, 8} under bursty host load: every 4th round
    the host batch build costs 3× (re-partitioning/logging spikes), with
    a 0.45× average host fraction — a load profile where each deeper
    window hides strictly more host time (window < burst cadence exposes
    every spike; window ≥ cadence amortizes it across in-flight rounds).
    """
    rounds = 12 if common.SMOKE else 24
    sweep = {}
    rows = []
    for w in WINDOWS:
        r = executor_overlap(model, cluster, rounds=rounds, window=w,
                             host_frac=0.45, host_burst_every=4,
                             host_burst_frac=3.0,
                             state_bytes=1 << 20)
        sweep[f"window{w}"] = {
            "rounds_per_s": r["rounds_per_s"],
            "hidden_host_frac_steady": r["hidden_host_frac_steady"],
            "host_s_exposed_steady": r["host_s_exposed_steady"],
            "peak_handle_ring_bytes": r["handle_bytes_peak"],
            "peak_in_flight": r["peak_in_flight"]}
        rows.append(Row(
            f"throughput/{tag}/executor_window{w}",
            1e6 * r["wall_s_per_round"],
            f"rounds_per_s={r['rounds_per_s']:.2f}"
            f";hidden_frac={r['hidden_host_frac_steady']:.2f}"
            f";handle_bytes={r['handle_bytes_peak']}"))
    s1 = sweep["window1"]["rounds_per_s"]
    rows.append(Row(f"throughput/{tag}/executor_speedup_w4_vs_w1", 0.0,
                    f"x={sweep['window4']['rounds_per_s']/max(s1,1e-9):.2f}"))
    record[f"{tag}_executor"] = sweep
    return rows


def run_checkpoint_overlap(model, cluster, tag, record):
    """Checkpoint-heavy scenario (window=4, save every 4 rounds, save
    cost 1.5× a device round): the flush saver drains 4 in-flight
    rounds, saves on an idle mesh and refills the pipe; the no-flush
    saver captures round r's handle at dispatch and saves while rounds
    r+1..r+4 execute."""
    rounds = 12 if common.SMOKE else 24
    kw = dict(rounds=rounds, window=4, host_frac=0.45,
              checkpoint_every=4, state_bytes=1 << 20)
    flush = executor_overlap(model, cluster, checkpoint_flush=True, **kw)
    noflush = executor_overlap(model, cluster, checkpoint_flush=False, **kw)
    rec = {
        "flush_rounds_per_s": flush["rounds_per_s"],
        "noflush_rounds_per_s": noflush["rounds_per_s"],
        "speedup": noflush["rounds_per_s"] /
        max(flush["rounds_per_s"], 1e-9),
        "flush_saves": flush["checkpoints"]["flush_saves"],
        "noflush_saves": noflush["checkpoints"]["noflush_saves"],
        "noflush_peak_handle_bytes": noflush["handle_bytes_peak"]}
    record[f"{tag}_checkpoint"] = rec
    return [
        Row(f"throughput/{tag}/ckpt_flush",
            1e6 * flush["wall_s_per_round"],
            f"rounds_per_s={flush['rounds_per_s']:.2f}"
            f";saves={rec['flush_saves']}"),
        Row(f"throughput/{tag}/ckpt_noflush",
            1e6 * noflush["wall_s_per_round"],
            f"rounds_per_s={noflush['rounds_per_s']:.2f}"
            f";saves={rec['noflush_saves']}"),
        Row(f"throughput/{tag}/ckpt_noflush_speedup", 0.0,
            f"x={rec['speedup']:.2f}"),
    ]


def main() -> list[Row]:
    record: dict = {"smoke": common.SMOKE, "duration_s": bench_duration(600.0)}
    registry = MetricsRegistry()
    rows = []
    rows += run(VGG5_SPLIT, testbed_a(), "A_vgg5", record, registry)
    rows += run(MOBILENET_SPLIT, testbed_b(), "B_mobilenet", record,
                registry)
    rows += run(TRANSFORMER6_SPLIT, testbed_a(), "A_transformer6", record,
                registry)
    rows += run(TRANSFORMER12_SPLIT, testbed_b(), "B_transformer12", record,
                registry)
    rows += run_executor_throughput(TRANSFORMER6_SPLIT, testbed_a(),
                                    "A_transformer6", record)
    rows += run_checkpoint_overlap(TRANSFORMER6_SPLIT, testbed_a(),
                                   "A_transformer6", record)
    write_record(OUT_PATH, record, registry=registry)
    rows.append(Row("throughput/json", 0.0,
                    f"wrote={os.path.basename(OUT_PATH)}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
