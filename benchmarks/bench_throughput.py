"""Fig. 10/11: system throughput (samples/s) per method, both testbeds."""
from __future__ import annotations

from repro.core.baselines import REGISTRY
from repro.core.simulation import simulate_fedoptima

from .common import (MOBILENET_SPLIT, OMEGA, Row, TRANSFORMER12_SPLIT,
                     TRANSFORMER6_SPLIT, VGG5_SPLIT, fedoptima_control,
                     testbed_a, testbed_b, timed)

DUR = 600.0


def run(model, cluster, tag):
    rows = []
    cp = fedoptima_control(cluster)
    fo, us = timed(simulate_fedoptima, model, cluster, duration=DUR,
                   omega=OMEGA, control=cp)
    assert cp.peak_buffered <= OMEGA
    rows.append(Row(f"throughput/{tag}/fedoptima", us,
                    f"samples_per_s={fo.throughput:.1f}"))
    best = 0.0
    for name, fn in REGISTRY.items():
        b, us = timed(fn, model, cluster, duration=DUR)
        rows.append(Row(f"throughput/{tag}/{name}", us,
                        f"samples_per_s={b.throughput:.1f}"))
        best = max(best, b.throughput)
    rows.append(Row(f"throughput/{tag}/speedup_vs_best_baseline", 0.0,
                    f"x={fo.throughput / max(best, 1e-9):.2f}"))
    return rows


def main() -> list[Row]:
    rows = []
    rows += run(VGG5_SPLIT, testbed_a(), "A_vgg5")
    rows += run(MOBILENET_SPLIT, testbed_b(), "B_mobilenet")
    rows += run(TRANSFORMER6_SPLIT, testbed_a(), "A_transformer6")
    rows += run(TRANSFORMER12_SPLIT, testbed_b(), "B_transformer12")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
