"""Fig. 10/11: system throughput (samples/s) per method, both testbeds.

Also measures executor round throughput: rounds/s driven through the
pipelined RoundExecutor at window=1 vs window=2 on a testbed-modeled
workload (the window-2 gain is the hidden host-plan/build time).  The
per-method numbers and the executor deltas are written to
``BENCH_throughput.json``.
"""
from __future__ import annotations

import json
import os

from repro.core.baselines import REGISTRY
from repro.core.simulation import simulate_fedoptima

from . import common
from .common import (MOBILENET_SPLIT, OMEGA, Row, TRANSFORMER12_SPLIT,
                     TRANSFORMER6_SPLIT, VGG5_SPLIT, bench_duration,
                     executor_overlap, fedoptima_control, testbed_a,
                     testbed_b, timed)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_throughput.json")


def run(model, cluster, tag, record):
    dur = bench_duration(600.0)
    rows = []
    cp = fedoptima_control(cluster)
    fo, us = timed(simulate_fedoptima, model, cluster, duration=dur,
                   omega=OMEGA, control=cp)
    assert cp.peak_buffered <= OMEGA
    rows.append(Row(f"throughput/{tag}/fedoptima", us,
                    f"samples_per_s={fo.throughput:.1f}"))
    best = 0.0
    for name, fn in REGISTRY.items():
        b, us = timed(fn, model, cluster, duration=dur)
        rows.append(Row(f"throughput/{tag}/{name}", us,
                        f"samples_per_s={b.throughput:.1f}"))
        best = max(best, b.throughput)
    speedup = fo.throughput / max(best, 1e-9)
    rows.append(Row(f"throughput/{tag}/speedup_vs_best_baseline", 0.0,
                    f"x={speedup:.2f}"))
    record[tag] = {"fedoptima_samples_per_s": fo.throughput,
                   "speedup_vs_best_baseline": speedup}
    return rows


def run_executor_throughput(model, cluster, tag, record):
    rounds = 8 if common.SMOKE else 20
    sync = executor_overlap(model, cluster, rounds=rounds, window=1)
    pipe = executor_overlap(model, cluster, rounds=rounds, window=2)
    rps_sync = 1.0 / max(sync["wall_s_per_round"], 1e-9)
    rps_pipe = 1.0 / max(pipe["wall_s_per_round"], 1e-9)
    rows = [
        Row(f"throughput/{tag}/executor_window1",
            1e6 * sync["wall_s_per_round"],
            f"rounds_per_s={rps_sync:.2f};in_flight={sync['peak_in_flight']}"),
        Row(f"throughput/{tag}/executor_window2",
            1e6 * pipe["wall_s_per_round"],
            f"rounds_per_s={rps_pipe:.2f};in_flight={pipe['peak_in_flight']}"
            f";host_ms_hidden={pipe['host_ms_hidden_per_round']:.2f}"),
        Row(f"throughput/{tag}/executor_speedup", 0.0,
            f"x={rps_pipe / max(rps_sync, 1e-9):.2f}"),
    ]
    record[f"{tag}_executor"] = {
        "window1_rounds_per_s": rps_sync,
        "window2_rounds_per_s": rps_pipe,
        "speedup": rps_pipe / max(rps_sync, 1e-9),
        "host_ms_hidden_per_round": pipe["host_ms_hidden_per_round"],
        "rounds_in_flight": pipe["peak_in_flight"]}
    return rows


def main() -> list[Row]:
    record: dict = {"smoke": common.SMOKE, "duration_s": bench_duration(600.0)}
    rows = []
    rows += run(VGG5_SPLIT, testbed_a(), "A_vgg5", record)
    rows += run(MOBILENET_SPLIT, testbed_b(), "B_mobilenet", record)
    rows += run(TRANSFORMER6_SPLIT, testbed_a(), "A_transformer6", record)
    rows += run(TRANSFORMER12_SPLIT, testbed_b(), "B_transformer12", record)
    rows += run_executor_throughput(TRANSFORMER6_SPLIT, testbed_a(),
                                    "A_transformer6", record)
    with open(OUT_PATH, "w") as fh:
        json.dump(record, fh, indent=2, sort_keys=True)
    rows.append(Row("throughput/json", 0.0,
                    f"wrote={os.path.basename(OUT_PATH)}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
