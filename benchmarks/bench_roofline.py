"""§Roofline table: reads the dry-run JSON records (results/dryrun) and
emits the per-(arch × shape) roofline terms; falls back to compiling the
three smallest cells live if no records exist."""
from __future__ import annotations

import glob
import json
import os

from .common import Row

_BASE = os.path.join(os.path.dirname(__file__), "..", "results")
RESULTS = os.path.join(_BASE, "final") if \
    os.path.isdir(os.path.join(_BASE, "final")) else \
    os.path.join(_BASE, "dryrun")


def _row_from_record(rec) -> list[Row]:
    if rec.get("status") == "skip":
        return [Row(f"roofline/{rec['arch']}/{rec['shape']}/{rec.get('mesh_kind','single')}",
                    0.0, f"SKIP:{rec['reason'][:60]}")]
    if rec.get("status") != "ok":
        return [Row(f"roofline/{rec['arch']}/{rec['shape']}/{rec.get('mesh_kind','single')}",
                    0.0, f"ERROR:{rec.get('error', '?')[:60]}")]
    t = rec["roofline_kernelized"]
    mem = rec["memory_analysis"]["temp_bytes"] / 1e9
    return [Row(
        f"roofline/{rec['arch']}/{rec['shape']}/{rec.get('mesh_kind','single')}",
        rec.get("compile_s", 0.0) * 1e6,
        f"compute_s={t['compute_s']:.4f};memory_s={t['memory_s']:.4f};"
        f"collective_s={t['collective_s']:.4f};dominant={t['dominant']};"
        f"mfu_bound={t['mfu_bound']:.3f};temp_GB={mem:.2f}")]


def main() -> list[Row]:
    rows = []
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        return [Row("roofline/no_records", 0.0,
                    "run `python -m repro.launch.dryrun --all --out "
                    "results/dryrun` first")]
    for f in files:
        with open(f) as fh:
            rows += _row_from_record(json.load(fh))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r.csv())
